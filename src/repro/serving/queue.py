"""Arrival queue and per-request lifecycle bookkeeping for the SSSP server.

A :class:`Request` is the unit of work the serving subsystem tracks: one
source vertex against the server's graph, stamped at every lifecycle edge
(arrival -> admission into a lane -> completion). Timestamps come from the
batcher's injectable clock, so the same code serves wall-clock production
loops and simulated-time benchmarks/tests.

:class:`ArrivalQueue` is a plain FIFO — admission order is arrival order.
Admission *policy* (priorities, deadline shedding, backpressure) lives in
the scheduler, which consumes this queue; the queue itself only adds the
re-enqueue path retries need (:meth:`ArrivalQueue.requeue`) and targeted
removal for overload shedding (:meth:`ArrivalQueue.remove`).

Every request retires with exactly one ``outcome``:

  * ``"ok"`` — answered (``dist`` carries the row; possibly late, see
    :attr:`Request.deadline_missed`).
  * ``"deadline"`` — shed unanswered because its deadline expired while it
    waited for a lane.
  * ``"shed"`` — dropped by overload shedding (a higher-priority arrival
    displaced it) or by server ``close()``.
  * ``"failed"`` — its retry budget ran out under persistent faults.

``None`` means still in flight. The scheduler's completion funnel raises on
any attempt to retire a request twice.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass(eq=False)
class Request:
    """One SSSP query and its lifecycle timestamps (all in clock units).

    Identity semantics (``eq=False``): requests are tracked by object, and a
    generated ``__eq__`` would compare the (n,) ``dist`` arrays elementwise
    — ambiguous-truth errors instead of booleans.
    """

    req_id: int
    source: int
    t_arrival: float
    target: int | None = None  # s->t query: only dist[target] is guaranteed
    #   on the completed row (None = ordinary full solve)
    priority: int = 0  # higher wins a lane first; FIFO within a priority
    deadline: float | None = None  # absolute clock time the answer is due
    stale_ok: bool = False  # accept a cached row older than the server TTL
    max_retries: int | None = None  # per-request retry budget override
    t_admitted: float | None = None
    t_completed: float | None = None
    lane: int | None = None  # None for cache hits (never occupied a lane)
    phases: int | None = None  # engine phases spent on this query (0 = cache hit)
    cache_hit: bool = False
    coalesced: bool = False  # deduplicated onto an in-flight identical query
    outcome: str | None = None  # "ok" | "deadline" | "shed" | "failed"
    retries: int = 0  # re-solves consumed (quarantine / engine recovery)
    not_before: float = 0.0  # backoff gate: not admitted before this time
    downgraded: bool = False  # point query widened to a cacheable full solve
    served_stale: bool = False  # answered from a cache row past the TTL
    fail_reason: str | None = None  # detector detail for non-"ok" outcomes
    dist: np.ndarray | None = dataclasses.field(default=None, repr=False)

    @property
    def effective_target(self) -> int | None:
        """The target the *engine* solves for: a downgraded point query runs
        (and caches/coalesces) as a full solve; ``distance`` still answers
        the original s->t question from the full row."""
        return None if self.downgraded else self.target

    @property
    def distance(self) -> float | None:
        """The query's scalar answer: ``dist[target]`` for an s->t query,
        None for full solves (read ``dist``) or while incomplete."""
        if self.dist is None or self.target is None:
            return None
        return float(self.dist[self.target])

    @property
    def latency(self) -> float | None:
        """Arrival-to-completion time; None while in flight."""
        if self.t_completed is None:
            return None
        return self.t_completed - self.t_arrival

    @property
    def queue_wait(self) -> float | None:
        """Arrival-to-admission time; None while queued."""
        if self.t_admitted is None:
            return None
        return self.t_admitted - self.t_arrival

    @property
    def deadline_missed(self) -> bool:
        """True once the request provably missed its deadline: shed
        unanswered, or answered after the deadline passed."""
        if self.deadline is None:
            return False
        if self.outcome in ("deadline", "shed", "failed"):
            return True
        return self.t_completed is not None and self.t_completed > self.deadline


class ArrivalQueue:
    """FIFO of pending requests with monotonically increasing ids."""

    def __init__(self):
        self._q: deque[Request] = deque()
        self._next_id = 0
        self.total_enqueued = 0
        self.total_requeued = 0

    def push(self, source: int, t_arrival: float,
             target: int | None = None, priority: int = 0,
             deadline: float | None = None, stale_ok: bool = False,
             max_retries: int | None = None) -> Request:
        req = Request(req_id=self._next_id, source=int(source),
                      t_arrival=float(t_arrival),
                      target=None if target is None else int(target),
                      priority=int(priority),
                      deadline=None if deadline is None else float(deadline),
                      stale_ok=bool(stale_ok),
                      max_retries=max_retries)
        self._next_id += 1
        self.total_enqueued += 1
        self._q.append(req)
        return req

    def requeue(self, req: Request) -> Request:
        """Re-enqueue an existing request (retry path): same object, same
        ``req_id`` — its identity is its history; only classification runs
        again."""
        self.total_requeued += 1
        self._q.append(req)
        return req

    def remove(self, req: Request) -> None:
        """Targeted removal (overload shedding); raises if absent."""
        self._q.remove(req)

    def pop(self) -> Request:
        return self._q.popleft()

    def peek(self) -> Request | None:
        return self._q[0] if self._q else None

    def __iter__(self):
        return iter(self._q)

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
