"""Point-to-point (s->t) queries: early-exit lanes and bidirectional search.

:func:`run_point_to_point` / :class:`PointBackend` answer single-pair
shortest-path queries against the batched phase stepper (DESIGN.md
Sec. 13). The *forward* lane is an ordinary target lane — it runs the
engine from ``source`` with ``BatchState.target = target``, so it inherits
both target optimisations: the lane early-exits the phase its target
settles, and the criterion policies prune relaxations past the target's
tentative distance. Its ``dist[target]`` is bit-exact against a full
``run_phased`` solve (the pruning-soundness argument lives with
``repro.kernels.ops._bound_gate``).

*Bidirectional* mode couples a second lane: the same engine run from
``target`` on the memoised transpose graph, whose labels satisfy
``d_b[v] == dist_g(v -> t)``. The two lanes share a best-seen meeting
bound ``mu = min_v fl(d_f[v] + d_b[v])`` — every tentative label is the
f32 length of a real path, so each ``mu`` candidate upper-bounds the exact
s->t distance. The bound is used for two *bitwise-safe* purposes only:

  * **backward retirement** — once the backward fringe's minimum distance
    passes ``mu``, no further backward phase can improve the bound, so the
    backward lane stops paying for phases;
  * **unreachability certification** — if the backward lane exhausts
    ``target``'s in-ball without reaching ``source``, no s->t path exists
    and the query answers ``inf`` immediately, while the forward lane
    alone would have had to flood ``source``'s entire out-component (its
    early exit never fires on an unreachable target).

``mu`` is deliberately NOT used to prune the forward lane or as the
answer: ``fl(d_f[v] + d_b[v])`` associates the path sum differently from
the forward left-to-right evaluation that defines the engine's bitwise
contract, so it can round *below* the forward-final ``dist[t]`` and would
break bit-exactness (DESIGN.md Sec. 13 spells out the rounding argument).
The authoritative answer is always the forward lane's ``dist[target]``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delta_stepping import default_delta
from repro.core.graph import (
    Graph,
    to_ell_in,
    to_ell_in_sliced,
    to_ell_out,
    to_ell_out_sliced,
    transpose,
)
from repro.core.static_engine import (
    DEFAULT_CRITERION,
    init_batch_state,
    step_batch,
)
from repro.serving.backends import _serving_policy

INF = float("inf")


def transpose_memo(g: Graph) -> Graph:
    """``transpose(g)``, memoised on the graph instance.

    The backward lane's adjacency; memoised so a server answering many
    s->t queries against one graph builds the reverse ELL exactly once.
    """
    tr = g.__dict__.get("_transpose")
    if tr is None:
        tr = transpose(g)
        g.__dict__["_transpose"] = tr
    return tr


@jax.jit
def _meet(df, db):
    """Best meeting bound over the current labels: ``(mu, argmin vertex)``.

    ``fl(df[v] + db[v])`` concatenates a real s->v path with a real v->t
    path, so every finite entry upper-bounds the exact s->t distance.
    """
    tot = df[0] + db[0]
    v = jnp.argmin(tot)
    return tot[v], v


@jax.jit
def _lane_stats(state):
    """One device read per lane per chunk: (live, phases, min fringe d)."""
    fringe = state.status[0] == 1
    return (
        jnp.any(fringe),
        state.phases[0],
        jnp.min(jnp.where(fringe, state.dist[0], jnp.inf)),
    )


@dataclasses.dataclass(frozen=True)
class PointResult:
    """One answered s->t query.

    ``distance`` (== ``dist[target]``) is bit-exact vs the full-solve
    ``run_phased`` row; the rest of ``dist`` is partial — goal-directed
    pruning only guarantees labels at or nearer than the target.
    """

    source: int
    target: int
    distance: float
    dist: np.ndarray  # forward lane's (n,) row; only dist[target] guaranteed
    phases_forward: int
    phases_backward: int  # 0 in forward-only mode
    mu: float  # best meeting bound seen (upper bound on distance)
    meeting_vertex: int | None
    unreachable_certified: bool  # backward lane proved no s->t path exists


class PointBackend:
    """Reusable s->t query engine over one graph (forward + backward views).

    Construction resolves the policy/layout exactly like
    :class:`~repro.serving.backends.StaticBackend`; the backward (transpose)
    adjacency is built lazily on the first bidirectional query and memoised,
    so forward-only use never pays for it. ``query`` answers one (s, t)
    pair; ``run_point_to_point`` wraps a per-graph memoised instance.
    """

    def __init__(self, g: Graph, *, criterion: str = DEFAULT_CRITERION,
                 policy: str | None = None, layout: str = "padded",
                 use_pallas: bool = True, bidirectional: bool = True,
                 phases_per_chunk: int = 8):
        spec = policy if policy is not None else criterion
        pol = _serving_policy(spec)
        if layout not in ("padded", "sliced"):
            raise ValueError(
                f"layout must be 'padded' or 'sliced'; got {layout!r}"
            )
        if phases_per_chunk < 1:
            raise ValueError(
                f"phases_per_chunk must be >= 1; got {phases_per_chunk}"
            )
        self.g = g
        self.layout = layout
        self.criterion = pol.spec
        self._pol = pol
        sliced = layout == "sliced"
        self.ell = to_ell_in_sliced(g) if sliced else to_ell_in(g)
        self.ell_out = None
        if pol.needs_out_adjacency:
            self.ell_out = to_ell_out_sliced(g) if sliced else to_ell_out(g)
        self.use_pallas = bool(use_pallas)
        self.bidirectional = bool(bidirectional)
        self.phases_per_chunk = int(phases_per_chunk)
        # same bucket width both directions: the transpose has the same
        # weight multiset, so default_delta agrees
        self.delta = default_delta(g) if pol.uses_delta else None
        self._bwd_views = None  # (gt, ell, ell_out) built on first use

    def _backward(self):
        if self._bwd_views is None:
            gt = transpose_memo(self.g)
            sliced = self.layout == "sliced"
            ell = to_ell_in_sliced(gt) if sliced else to_ell_in(gt)
            ell_out = None
            if self._pol.needs_out_adjacency:
                ell_out = to_ell_out_sliced(gt) if sliced else to_ell_out(gt)
            self._bwd_views = (gt, ell, ell_out)
        return self._bwd_views

    def query(self, source: int, target: int) -> PointResult:
        """Answer one s->t query; ``distance`` is bit-exact vs run_phased."""
        n = self.g.n
        source, target = int(source), int(target)
        for name, v in (("source", source), ("target", target)):
            if not 0 <= v < n:
                raise ValueError(f"{name} must be in [0, {n}); got {v}")
        fwd = init_batch_state(
            self.g, np.array([source], np.int32), criterion=self.criterion,
            delta=self.delta, targets=np.array([target], np.int32),
        )
        bwd = bwd_graph = bwd_ell = bwd_ell_out = None
        if self.bidirectional:
            bwd_graph, bwd_ell, bwd_ell_out = self._backward()
            bwd = init_batch_state(
                bwd_graph, np.array([target], np.int32),
                criterion=self.criterion, delta=self.delta,
                targets=np.array([source], np.int32),
            )
        k = self.phases_per_chunk
        cap = self._pol.phase_cap(n)
        mu, meet_v = INF, None
        phases_b = 0
        bwd_live = bwd is not None
        unreachable = False
        while True:
            fwd = step_batch(
                self.g, fwd, k, ell=self.ell, use_pallas=self.use_pallas,
                stop_on_lane_finish=True, ell_out=self.ell_out,
            )
            f_live, f_phases, _ = (np.asarray(x) for x in _lane_stats(fwd))
            if not f_live:
                break
            if bwd_live:
                bwd = step_batch(
                    bwd_graph, bwd, k, ell=bwd_ell,
                    use_pallas=self.use_pallas, stop_on_lane_finish=True,
                    ell_out=bwd_ell_out,
                )
                b_live, b_phases, b_min = (
                    np.asarray(x) for x in _lane_stats(bwd)
                )
                phases_b = int(b_phases)
                m, v = _meet(fwd.dist, bwd.dist)
                if float(m) < mu:
                    mu, meet_v = float(m), int(v)
                if not b_live:
                    bwd_live = False
                    if float(np.asarray(bwd.dist[0, source])) == INF:
                        # the backward lane exhausted target's in-ball
                        # without reaching source (its own early exit only
                        # fires on a *finite* settle), so no s->t path
                        # exists — stop flooding the forward component
                        unreachable = True
                        break
                elif float(b_min) >= mu:
                    # no backward fringe vertex can improve mu any more;
                    # retire the lane, the forward lane owns the answer
                    bwd_live = False
            if int(f_phases) >= cap:
                raise RuntimeError(
                    f"s->t query exceeded the policy phase cap {cap}; "
                    "the engine should terminate within it on any input"
                )
        row = np.asarray(fwd.dist[0])
        return PointResult(
            source=source,
            target=target,
            distance=float(row[target]),
            dist=row,
            phases_forward=int(np.asarray(fwd.phases)[0]),
            phases_backward=phases_b,
            mu=mu,
            meeting_vertex=meet_v,
            unreachable_certified=unreachable,
        )


def run_point_to_point(
    g: Graph,
    source: int,
    target: int,
    *,
    criterion: str = DEFAULT_CRITERION,
    policy: str | None = None,
    layout: str = "padded",
    use_pallas: bool = True,
    bidirectional: bool = True,
    phases_per_chunk: int = 8,
) -> PointResult:
    """One-shot s->t query (memoises one :class:`PointBackend` per config).

    The backend is cached on the graph instance keyed by the resolved
    configuration, so repeated calls against one graph reuse the forward
    and transpose adjacency views and all compiled programs.
    """
    cache = g.__dict__.setdefault("_point_backends", {})
    spec = policy if policy is not None else criterion
    key = (spec, layout, bool(use_pallas), bool(bidirectional),
           int(phases_per_chunk))
    backend = cache.get(key)
    if backend is None:
        backend = PointBackend(
            g, criterion=criterion, policy=policy, layout=layout,
            use_pallas=use_pallas, bidirectional=bidirectional,
            phases_per_chunk=phases_per_chunk,
        )
        cache[key] = backend
    return backend.query(source, target)
