"""Engine backend adapters: the seam between scheduler and solver.

:class:`ContinuousBatcher` never touches an engine directly — it drives an
:class:`EngineBackend`, a five-method adapter (``init`` / ``step`` /
``reset_lanes`` / ``peek`` / ``take_row``) over any resumable B-lane phase
stepper. Two implementations exist:

  * :class:`StaticBackend` — the single-device Pallas stepper
    (``repro.core.static_engine``): ``(B, n)`` state, ELL pull kernels.
  * :class:`ShardedBackend` — the mesh stepper
    (``repro.core.distributed``): ``(B, n_pad)`` state block-sharded over
    the mesh's vertex axis, COO push + one vector collective per phase.

Both expose identical semantics — a lane is a fixed point when empty or
finished, a reset lane is bitwise a fresh solve, ``stop_on_lane_finish``
ends a chunk on the first lane termination — so the scheduler's
admission/coalescing/cache/metrics machinery is backend-agnostic and every
completed request's distances are bit-exact against a standalone
``run_phased_static`` solve regardless of which engine served it
(pinned by the shared parametrised test in ``tests/test_serving.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import criteria as C
from repro.core import policies as P
from repro.core.delta_stepping import default_delta
from repro.core.graph import (
    Graph,
    out_degrees,
    to_ell_in,
    to_ell_in_sliced,
    to_ell_out,
    to_ell_out_sliced,
)
from repro.core.static_engine import (
    DEFAULT_CRITERION,
    EMPTY_LANE,
    BatchState,
    init_batch_state,
    reset_lanes,
    run_phased_static_batch,
    step_batch,
)


def _serving_plan(criterion: str) -> C.CritPlan:
    """Validate and canonicalise a serving criterion.

    'oracle' is rejected: a clairvoyant criterion needs the answer it is
    supposed to compute as input, which no online workload has.
    """
    plan = C.plan_for(criterion)
    if plan.needs_oracle:
        raise ValueError(
            "serving backends cannot run the 'oracle' criterion: it requires "
            "per-query true distances up front"
        )
    return plan


def _serving_policy(spec: str) -> P.PhasePolicy:
    """Validate and resolve a serving policy spec (criterion or "delta").

    Same oracle rejection as :func:`_serving_plan`, lifted to the policy
    layer so delta-stepping backends pass through.
    """
    pol = P.policy_for(spec)
    if pol.needs_oracle:
        raise ValueError(
            "serving backends cannot run the 'oracle' criterion: it requires "
            "per-query true distances up front"
        )
    return pol


@jax.jit
def _peek(state):
    """One fused device read per step: (trips, per-lane live flag, phases)."""
    return state.trips, jnp.any(state.status == 1, axis=1), state.phases


@jax.jit
def _take_row(dist, lane):
    # traced lane index -> one compile total (a python-int index or a
    # variable-length fancy-index would recompile per lane / per count)
    return jax.lax.dynamic_index_in_dim(dist, lane, keepdims=False)


@runtime_checkable
class EngineBackend(Protocol):
    """What the scheduler needs from a resumable B-lane engine."""

    g: Graph
    criterion: str  # canonical criterion string the engine solves with —
    #   part of the serving cache key: rows computed under different criteria
    #   coincide only in exact arithmetic, so they must never share entries

    @property
    def n(self) -> int:
        """Vertex count queries are validated against."""
        ...

    def init(self, lanes: int):
        """Fresh all-empty state with ``lanes`` lanes."""
        ...

    def step(self, state, k_phases: int, *, stop_on_lane_finish: bool = True,
             donate: bool = False):
        """Advance up to ``k_phases`` trips (early exit on lane finish)."""
        ...

    def reset_lanes(self, state, sources: np.ndarray, *, donate: bool = False):
        """Re-init the lanes ``sources`` selects (KEEP_LANE passes through)."""
        ...

    def peek(self, state) -> tuple[int, np.ndarray, np.ndarray]:
        """(trips, (B,) bool live flags, (B,) int phases) — one device sync."""
        ...

    def take_row(self, state, lane: int) -> np.ndarray:
        """Lane ``lane``'s (n,) f32 distance row as a fresh host-owned array
        (never aliasing the state buffers — the scheduler donates those to
        the next engine call)."""
        ...


class StaticBackend:
    """Adapter over the single-device static-engine stepper.

    ``layout`` selects the resident adjacency views ("padded" ELL or the
    degree-sliced "sliced" layout — bit-identical results, the sliced one
    wins on skewed degree distributions); an explicit ``ell`` overrides it.
    ``policy`` accepts any policy spec (criterion disjunction or
    ``"delta"``) and takes precedence over ``criterion`` — the two
    keywords exist so pre-portfolio callers keep working; ``delta`` is the
    bucket width for the delta policy (default ``default_delta(g)``).
    Execution mode / tile sizes resolve through ``repro.kernels.config``
    (env overrides + tuning ledger), so a server process tuned at startup
    serves every later query with the tuned configuration.
    """

    def __init__(self, g: Graph, ell=None, use_pallas: bool = True,
                 criterion: str = DEFAULT_CRITERION, layout: str = "padded",
                 policy: str | None = None, delta: float | None = None):
        spec = policy if policy is not None else criterion
        pol = _serving_policy(spec)
        if layout not in ("padded", "sliced"):
            raise ValueError(
                f"layout must be 'padded' or 'sliced'; got {layout!r}"
            )
        sliced = layout == "sliced"
        self.g = g
        if ell is None:
            ell = to_ell_in_sliced(g) if sliced else to_ell_in(g)
        self.ell = ell
        self.ell_out = None
        if pol.needs_out_adjacency:
            self.ell_out = to_ell_out_sliced(g) if sliced else to_ell_out(g)
        self.use_pallas = bool(use_pallas)
        self.criterion = pol.spec
        self.delta = None
        if pol.uses_delta:
            self.delta = float(delta) if delta is not None else default_delta(g)
        elif delta is not None:
            raise ValueError(
                f"policy {pol.spec!r} does not take a delta bucket width; "
                "use policy='delta' for delta-stepping"
            )

    @property
    def n(self) -> int:
        return self.g.n

    def init(self, lanes: int) -> BatchState:
        return init_batch_state(self.g, np.full(lanes, EMPTY_LANE, np.int32),
                                criterion=self.criterion, delta=self.delta)

    def step(self, state, k_phases, *, stop_on_lane_finish=True, donate=False):
        return step_batch(
            self.g, state, k_phases, ell=self.ell, use_pallas=self.use_pallas,
            stop_on_lane_finish=stop_on_lane_finish, donate=donate,
            ell_out=self.ell_out,
        )

    def reset_lanes(self, state, sources, *, donate=False):
        return reset_lanes(state, sources, donate=donate)

    def peek(self, state):
        trips, active, phases = _peek(state)
        return int(trips), np.asarray(active), np.asarray(phases)

    def take_row(self, state, lane):
        return np.asarray(_take_row(state.dist, jnp.int32(lane)))


class ShardedBackend:
    """Adapter over the mesh-sharded batch stepper.

    The same scheduler then serves continuous traffic against a graph whose
    vertex state lives block-partitioned across the device mesh — lanes are
    rows of the ``(B, n_pad)`` sharded state, and each scheduling round's
    ``step`` runs the fused shard_map phase loop (DESIGN.md Sec. 7).
    """

    def __init__(self, g: Graph, mesh, axes, schedule: str = "reduce_scatter",
                 pad_multiple: int = 8, criterion: str = DEFAULT_CRITERION):
        # imported lazily-ish at construction: the distributed module pulls
        # in shard_map machinery the static serving path never needs
        from repro.core.distributed import shard_graph_batch

        if isinstance(axes, str):
            axes = (axes,)
        self.g = g
        self.mesh = mesh
        self.axes = tuple(axes)
        self.schedule = schedule
        plan = _serving_plan(criterion)
        self.criterion = plan.criterion
        num = int(np.prod([mesh.shape[a] for a in self.axes]))
        # the backend's criterion is fixed for its lifetime, so only build
        # the transpose edge partition when the plan's dynamic OUT keys
        # will actually read it (it doubles resident edge memory)
        self.sg = shard_graph_batch(g, num, pad_multiple=pad_multiple,
                                    with_transpose=plan.needs_out_adjacency)

    @property
    def n(self) -> int:
        return self.g.n

    def init(self, lanes: int):
        from repro.core.distributed import init_sharded_batch_state

        return init_sharded_batch_state(
            self.sg, np.full(lanes, EMPTY_LANE, np.int32),
            criterion=self.criterion,
        )

    def step(self, state, k_phases, *, stop_on_lane_finish=True, donate=False):
        from repro.core.distributed import step_sharded_batch

        return step_sharded_batch(
            self.sg, state, self.mesh, self.axes, k_phases,
            schedule=self.schedule, stop_on_lane_finish=stop_on_lane_finish,
            donate=donate,
        )

    def reset_lanes(self, state, sources, *, donate=False):
        from repro.core.distributed import reset_sharded_lanes

        return reset_sharded_lanes(state, sources, donate=donate)

    def peek(self, state):
        trips, active, phases = _peek(state)
        return int(trips), np.asarray(active), np.asarray(phases)

    def take_row(self, state, lane):
        # slice off the padding columns so consumers (cache, parity checks)
        # see the same (n,) row shape as the static backend
        return np.asarray(_take_row(state.dist, jnp.int32(lane)))[: state.n]


# ---------------------------------------------------------------------------
# Engine portfolio: measured policy x layout routing
# ---------------------------------------------------------------------------


def graph_family(g: Graph) -> str:
    """Coarse degree-distribution bucket the portfolio ledger keys on.

    ``max/mean`` out-degree >= 4 reads as a skewed (power-law-ish) graph —
    the regime where the sliced layout and bucketed scheduling pay off —
    everything else as flat. Two buckets is deliberately crude: the ledger
    records *measurements*, so a family only needs to be stable enough that
    graphs sharing it rank the candidates the same way.
    """
    deg = np.asarray(out_degrees(g), np.float64)
    mean = float(deg.mean()) if deg.size else 0.0
    if mean <= 0.0:
        return "flat"
    return "skew" if float(deg.max()) / mean >= 4.0 else "flat"


@dataclasses.dataclass(frozen=True)
class EngineCandidate:
    """One engine configuration the portfolio may route a workload to."""

    policy: str  # policy spec ("in|out", "delta", ...)
    layout: str  # "padded" | "sliced"
    delta: float | None = None  # bucket width override (delta policy only)

    @property
    def spec(self) -> str:
        return P.canonical_spec(self.policy)


DEFAULT_CANDIDATES: tuple[EngineCandidate, ...] = (
    EngineCandidate("instatic|outstatic", "padded"),
    EngineCandidate("in|out", "padded"),
    EngineCandidate("in|out", "sliced"),
    EngineCandidate("delta", "padded"),
    EngineCandidate("delta", "sliced"),
)


def _attribution_totals(result, spec: str) -> dict[str, int]:
    """Sum the harvested ``settle_attribution`` ring over lanes and phases,
    restricted to the policy's share terms (criterion members, or
    light/heavy for delta — the bucket-id gauge is not summable)."""
    if result.settle_attribution is None:
        return {}
    pol = P.policy_for(spec)
    terms = pol.attribution_terms()
    share = set(pol.share_terms())
    attr = np.asarray(result.settle_attribution)  # (B, trace_len, T)
    return {
        t: int(attr[:, :, k].sum())
        for k, t in enumerate(terms)
        if t in share
    }


def measure_portfolio(
    g: Graph,
    *,
    lanes: int = 8,
    candidates: tuple[EngineCandidate, ...] = DEFAULT_CANDIDATES,
    ledger=None,
    use_pallas: bool = True,
    registry=None,
    repeats: int = 2,
) -> dict[tuple[str, str], dict]:
    """Probe every candidate on ``g`` and record measured entries.

    Each candidate solves the same ``lanes``-source batch twice: once with
    telemetry (doubles as compile warmup; yields phase counts and the
    policy's settle-attribution shares) and then timed without telemetry
    (median of ``repeats``). Entries land in the tuning ledger under
    :func:`~repro.kernels.config.portfolio_ledger_key` so later processes
    can route without re-probing; returns (policy, layout) -> entry.
    """
    from repro.kernels import config as kcfg
    from repro.obs.timer import timed

    if ledger is None:
        ledger = kcfg.global_ledger()
    family = graph_family(g)
    sources = (np.arange(lanes, dtype=np.int64) * 7919) % g.n
    out: dict[tuple[str, str], dict] = {}
    for cand in candidates:
        spec = cand.spec
        pol = P.policy_for(spec)
        kw: dict = {"criterion": spec, "layout": cand.layout,
                    "use_pallas": use_pallas}
        if pol.uses_delta:
            kw["delta"] = cand.delta  # None -> default_delta(g) downstream
        probe = run_phased_static_batch(
            g, sources, trace_len=pol.phase_cap(g.n), telemetry=True, **kw
        )
        jax.block_until_ready(probe.dist)

        def solve(kw=kw):
            return jax.block_until_ready(
                run_phased_static_batch(g, sources, **kw).dist
            )

        # the telemetry probe compiled a *different* program (rings on),
        # so warm the timed one explicitly — timed() has no implicit warmup
        solve()
        wall_s, _ = timed(solve, repeats=repeats)
        entry = kcfg.record_portfolio(
            ledger, family, lanes, spec, cand.layout,
            wall_s=wall_s,
            phases=int(np.asarray(probe.phases).sum()),
            queries=lanes,
            delta=cand.delta,
            attribution=_attribution_totals(probe, spec),
        )
        out[(spec, cand.layout)] = entry
        if registry is not None:
            registry.gauge(
                f"portfolio.qps.{spec}.{cand.layout}",
                "measured queries/s for one portfolio candidate",
            ).set(entry["qps"])
    return out


def pick_engine(
    family: str,
    lanes: int,
    candidates: tuple[EngineCandidate, ...] = DEFAULT_CANDIDATES,
    ledger=None,
) -> EngineCandidate:
    """The measured-best candidate for (family, lanes) from the ledger.

    Ranks by recorded qps over the candidates that have entries; with no
    entries at all the first candidate (the paper's default criterion) is
    the safe fallback — routing never blocks on a probe.
    """
    from repro.kernels import config as kcfg

    if ledger is None:
        ledger = kcfg.global_ledger()
    entries = kcfg.portfolio_entries(ledger, family, lanes)
    best, best_qps = None, -1.0
    for cand in candidates:
        entry = entries.get((cand.spec, cand.layout))
        if entry is not None and entry.get("qps", 0.0) > best_qps:
            best, best_qps = cand, float(entry["qps"])
    return best if best is not None else candidates[0]


class PortfolioBackend:
    """An :class:`EngineBackend` that picks its engine from the ledger.

    At construction it resolves ``graph_family(g)``, consults the tuning
    ledger's portfolio records for that (family, lanes) and instantiates
    the measured-best policy x layout as an inner :class:`StaticBackend`
    (``probe=True`` — or an empty ledger — runs :func:`measure_portfolio`
    first, so the first server against a new family pays one probe and
    every later one routes from the recorded entries). All five protocol
    methods delegate, so the scheduler sees an ordinary backend whose
    ``criterion`` reflects the routed policy.
    """

    def __init__(self, g: Graph, lanes_hint: int = 8,
                 candidates: tuple[EngineCandidate, ...] = DEFAULT_CANDIDATES,
                 ledger=None, use_pallas: bool = True, probe: bool = False,
                 registry=None):
        from repro.kernels import config as kcfg

        if not candidates:
            raise ValueError("candidates must be non-empty")
        if ledger is None:
            ledger = kcfg.global_ledger()
        self.family = graph_family(g)
        self.lanes_hint = int(lanes_hint)
        if probe or not kcfg.portfolio_entries(ledger, self.family,
                                               self.lanes_hint):
            measure_portfolio(
                g, lanes=self.lanes_hint, candidates=candidates,
                ledger=ledger, use_pallas=use_pallas, registry=registry,
            )
        self.choice = pick_engine(self.family, self.lanes_hint, candidates,
                                  ledger)
        self.inner = StaticBackend(
            g, use_pallas=use_pallas, layout=self.choice.layout,
            policy=self.choice.policy, delta=self.choice.delta,
        )
        self.g = g
        self.criterion = self.inner.criterion

    @property
    def n(self) -> int:
        return self.inner.n

    def init(self, lanes: int) -> BatchState:
        return self.inner.init(lanes)

    def step(self, state, k_phases, *, stop_on_lane_finish=True, donate=False):
        return self.inner.step(state, k_phases,
                               stop_on_lane_finish=stop_on_lane_finish,
                               donate=donate)

    def reset_lanes(self, state, sources, *, donate=False):
        return self.inner.reset_lanes(state, sources, donate=donate)

    def peek(self, state):
        return self.inner.peek(state)

    def take_row(self, state, lane):
        return self.inner.take_row(state, lane)
