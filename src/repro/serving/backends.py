"""Engine backend adapters: the seam between scheduler and solver.

:class:`ContinuousBatcher` never touches an engine directly — it drives an
:class:`EngineBackend`, a five-method adapter (``init`` / ``step`` /
``reset_lanes`` / ``peek`` / ``take_row``) over any resumable B-lane phase
stepper. Two implementations exist:

  * :class:`StaticBackend` — the single-device Pallas stepper
    (``repro.core.static_engine``): ``(B, n)`` state, ELL pull kernels.
  * :class:`ShardedBackend` — the mesh stepper
    (``repro.core.distributed``): ``(B, n_pad)`` state block-sharded over
    the mesh's vertex axis, COO push + one vector collective per phase.

Both expose identical semantics — a lane is a fixed point when empty or
finished, a reset lane is bitwise a fresh solve, ``stop_on_lane_finish``
ends a chunk on the first lane termination — so the scheduler's
admission/coalescing/cache/metrics machinery is backend-agnostic and every
completed request's distances are bit-exact against a standalone
``run_phased_static`` solve regardless of which engine served it
(pinned by the shared parametrised test in ``tests/test_serving.py``).
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, to_ell_in
from repro.core.static_engine import (
    EMPTY_LANE,
    BatchState,
    init_batch_state,
    reset_lanes,
    step_batch,
)


@jax.jit
def _peek(state):
    """One fused device read per step: (trips, per-lane live flag, phases)."""
    return state.trips, jnp.any(state.status == 1, axis=1), state.phases


@jax.jit
def _take_row(dist, lane):
    # traced lane index -> one compile total (a python-int index or a
    # variable-length fancy-index would recompile per lane / per count)
    return jax.lax.dynamic_index_in_dim(dist, lane, keepdims=False)


@runtime_checkable
class EngineBackend(Protocol):
    """What the scheduler needs from a resumable B-lane engine."""

    g: Graph

    @property
    def n(self) -> int:
        """Vertex count queries are validated against."""
        ...

    def init(self, lanes: int):
        """Fresh all-empty state with ``lanes`` lanes."""
        ...

    def step(self, state, k_phases: int, *, stop_on_lane_finish: bool = True,
             donate: bool = False):
        """Advance up to ``k_phases`` trips (early exit on lane finish)."""
        ...

    def reset_lanes(self, state, sources: np.ndarray, *, donate: bool = False):
        """Re-init the lanes ``sources`` selects (KEEP_LANE passes through)."""
        ...

    def peek(self, state) -> tuple[int, np.ndarray, np.ndarray]:
        """(trips, (B,) bool live flags, (B,) int phases) — one device sync."""
        ...

    def take_row(self, state, lane: int) -> np.ndarray:
        """Lane ``lane``'s (n,) f32 distance row as a fresh host-owned array
        (never aliasing the state buffers — the scheduler donates those to
        the next engine call)."""
        ...


class StaticBackend:
    """Adapter over the single-device static-engine stepper."""

    def __init__(self, g: Graph, ell=None, use_pallas: bool = True):
        self.g = g
        self.ell = to_ell_in(g) if ell is None else ell
        self.use_pallas = bool(use_pallas)

    @property
    def n(self) -> int:
        return self.g.n

    def init(self, lanes: int) -> BatchState:
        return init_batch_state(self.g, np.full(lanes, EMPTY_LANE, np.int32))

    def step(self, state, k_phases, *, stop_on_lane_finish=True, donate=False):
        return step_batch(
            self.g, state, k_phases, ell=self.ell, use_pallas=self.use_pallas,
            stop_on_lane_finish=stop_on_lane_finish, donate=donate,
        )

    def reset_lanes(self, state, sources, *, donate=False):
        return reset_lanes(state, sources, donate=donate)

    def peek(self, state):
        trips, active, phases = _peek(state)
        return int(trips), np.asarray(active), np.asarray(phases)

    def take_row(self, state, lane):
        return np.asarray(_take_row(state.dist, jnp.int32(lane)))


class ShardedBackend:
    """Adapter over the mesh-sharded batch stepper.

    The same scheduler then serves continuous traffic against a graph whose
    vertex state lives block-partitioned across the device mesh — lanes are
    rows of the ``(B, n_pad)`` sharded state, and each scheduling round's
    ``step`` runs the fused shard_map phase loop (DESIGN.md Sec. 7).
    """

    def __init__(self, g: Graph, mesh, axes, schedule: str = "reduce_scatter",
                 pad_multiple: int = 8):
        # imported lazily-ish at construction: the distributed module pulls
        # in shard_map machinery the static serving path never needs
        from repro.core.distributed import shard_graph_batch

        if isinstance(axes, str):
            axes = (axes,)
        self.g = g
        self.mesh = mesh
        self.axes = tuple(axes)
        self.schedule = schedule
        num = int(np.prod([mesh.shape[a] for a in self.axes]))
        self.sg = shard_graph_batch(g, num, pad_multiple=pad_multiple)

    @property
    def n(self) -> int:
        return self.g.n

    def init(self, lanes: int):
        from repro.core.distributed import init_sharded_batch_state

        return init_sharded_batch_state(
            self.sg, np.full(lanes, EMPTY_LANE, np.int32)
        )

    def step(self, state, k_phases, *, stop_on_lane_finish=True, donate=False):
        from repro.core.distributed import step_sharded_batch

        return step_sharded_batch(
            self.sg, state, self.mesh, self.axes, k_phases,
            schedule=self.schedule, stop_on_lane_finish=stop_on_lane_finish,
            donate=donate,
        )

    def reset_lanes(self, state, sources, *, donate=False):
        from repro.core.distributed import reset_sharded_lanes

        return reset_sharded_lanes(state, sources, donate=donate)

    def peek(self, state):
        trips, active, phases = _peek(state)
        return int(trips), np.asarray(active), np.asarray(phases)

    def take_row(self, state, lane):
        # slice off the padding columns so consumers (cache, parity checks)
        # see the same (n,) row shape as the static backend
        return np.asarray(_take_row(state.dist, jnp.int32(lane)))[: state.n]
