"""Engine backend adapters: the seam between scheduler and solver.

:class:`ContinuousBatcher` never touches an engine directly — it drives an
:class:`EngineBackend`, a five-method adapter (``init`` / ``step`` /
``reset_lanes`` / ``peek`` / ``take_row``) over any resumable B-lane phase
stepper. Two implementations exist:

  * :class:`StaticBackend` — the single-device Pallas stepper
    (``repro.core.static_engine``): ``(B, n)`` state, ELL pull kernels.
  * :class:`ShardedBackend` — the mesh stepper
    (``repro.core.distributed``): ``(B, n_pad)`` state block-sharded over
    the mesh's vertex axis, COO push + one vector collective per phase.

Both expose identical semantics — a lane is a fixed point when empty or
finished, a reset lane is bitwise a fresh solve, ``stop_on_lane_finish``
ends a chunk on the first lane termination — so the scheduler's
admission/coalescing/cache/metrics machinery is backend-agnostic and every
completed request's distances are bit-exact against a standalone
``run_phased_static`` solve regardless of which engine served it
(pinned by the shared parametrised test in ``tests/test_serving.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import criteria as C
from repro.core import policies as P
from repro.core.delta_stepping import default_delta
from repro.core.graph import (
    Graph,
    out_degrees,
    to_ell_in,
    to_ell_in_sliced,
    to_ell_out,
    to_ell_out_sliced,
)
from repro.core.static_engine import (
    DEFAULT_CRITERION,
    EMPTY_LANE,
    BatchState,
    init_batch_state,
    reset_lanes,
    run_phased_static_batch,
    step_batch,
)


def _serving_plan(criterion: str) -> C.CritPlan:
    """Validate and canonicalise a serving criterion.

    'oracle' is rejected: a clairvoyant criterion needs the answer it is
    supposed to compute as input, which no online workload has.
    """
    plan = C.plan_for(criterion)
    if plan.needs_oracle:
        raise ValueError(
            "serving backends cannot run the 'oracle' criterion: it requires "
            "per-query true distances up front"
        )
    return plan


def _serving_policy(spec: str) -> P.PhasePolicy:
    """Validate and resolve a serving policy spec (criterion or "delta").

    Same oracle rejection as :func:`_serving_plan`, lifted to the policy
    layer so delta-stepping backends pass through.
    """
    pol = P.policy_for(spec)
    if pol.needs_oracle:
        raise ValueError(
            "serving backends cannot run the 'oracle' criterion: it requires "
            "per-query true distances up front"
        )
    return pol


@jax.jit
def _peek(state):
    """One fused device read per step: (trips, per-lane live flag, phases)."""
    return state.trips, jnp.any(state.status == 1, axis=1), state.phases


@jax.jit
def _take_row(dist, lane):
    # traced lane index -> one compile total (a python-int index or a
    # variable-length fancy-index would recompile per lane / per count)
    return jax.lax.dynamic_index_in_dim(dist, lane, keepdims=False)


@runtime_checkable
class EngineBackend(Protocol):
    """What the scheduler needs from a resumable B-lane engine."""

    g: Graph
    criterion: str  # canonical criterion string the engine solves with —
    #   part of the serving cache key: rows computed under different criteria
    #   coincide only in exact arithmetic, so they must never share entries

    @property
    def n(self) -> int:
        """Vertex count queries are validated against."""
        ...

    def init(self, lanes: int):
        """Fresh all-empty state with ``lanes`` lanes."""
        ...

    def step(self, state, k_phases: int, *, stop_on_lane_finish: bool = True,
             donate: bool = False):
        """Advance up to ``k_phases`` trips (early exit on lane finish)."""
        ...

    def reset_lanes(self, state, sources: np.ndarray, *, donate: bool = False,
                    targets: np.ndarray | None = None):
        """Re-init the lanes ``sources`` selects (KEEP_LANE passes through).

        ``targets`` (point-capable backends only) gives each admitted lane
        its s->t target vertex, ``EMPTY_LANE`` for a full solve."""
        ...

    def peek(self, state) -> tuple[int, np.ndarray, np.ndarray]:
        """(trips, (B,) bool live flags, (B,) int phases) — one device sync."""
        ...

    def take_row(self, state, lane: int) -> np.ndarray:
        """Lane ``lane``'s (n,) f32 distance row as a fresh host-owned array
        (never aliasing the state buffers — the scheduler donates those to
        the next engine call)."""
        ...


class StaticBackend:
    """Adapter over the single-device static-engine stepper.

    ``layout`` selects the resident adjacency views ("padded" ELL or the
    degree-sliced "sliced" layout — bit-identical results, the sliced one
    wins on skewed degree distributions); an explicit ``ell`` overrides it.
    ``policy`` accepts any policy spec (criterion disjunction or
    ``"delta"``) and takes precedence over ``criterion`` — the two
    keywords exist so pre-portfolio callers keep working; ``delta`` is the
    bucket width for the delta policy (default ``default_delta(g)``).
    Execution mode / tile sizes resolve through ``repro.kernels.config``
    (env overrides + tuning ledger), so a server process tuned at startup
    serves every later query with the tuned configuration.

    ``point_queries=True`` initialises target-capable lane state (the
    pytree-structural ``BatchState.target`` field, DESIGN.md Sec. 13), so
    the scheduler can mix full solves and early-exiting s->t lanes in one
    batch. Off by default: a target-free server stays bit-identical to the
    pre-target engine program.
    """

    def __init__(self, g: Graph, ell=None, use_pallas: bool = True,
                 criterion: str = DEFAULT_CRITERION, layout: str = "padded",
                 policy: str | None = None, delta: float | None = None,
                 point_queries: bool = False):
        spec = policy if policy is not None else criterion
        pol = _serving_policy(spec)
        if layout not in ("padded", "sliced"):
            raise ValueError(
                f"layout must be 'padded' or 'sliced'; got {layout!r}"
            )
        sliced = layout == "sliced"
        self.g = g
        if ell is None:
            ell = to_ell_in_sliced(g) if sliced else to_ell_in(g)
        self.ell = ell
        self.ell_out = None
        if pol.needs_out_adjacency:
            self.ell_out = to_ell_out_sliced(g) if sliced else to_ell_out(g)
        self.use_pallas = bool(use_pallas)
        self.criterion = pol.spec
        self.point_queries = bool(point_queries)
        self.delta = None
        if pol.uses_delta:
            self.delta = float(delta) if delta is not None else default_delta(g)
        elif delta is not None:
            raise ValueError(
                f"policy {pol.spec!r} does not take a delta bucket width; "
                "use policy='delta' for delta-stepping"
            )

    @property
    def n(self) -> int:
        return self.g.n

    def init(self, lanes: int) -> BatchState:
        empty = np.full(lanes, EMPTY_LANE, np.int32)
        return init_batch_state(
            self.g, empty, criterion=self.criterion, delta=self.delta,
            targets=empty if self.point_queries else None,
        )

    def step(self, state, k_phases, *, stop_on_lane_finish=True, donate=False):
        return step_batch(
            self.g, state, k_phases, ell=self.ell, use_pallas=self.use_pallas,
            stop_on_lane_finish=stop_on_lane_finish, donate=donate,
            ell_out=self.ell_out,
        )

    def reset_lanes(self, state, sources, *, donate=False, targets=None):
        return reset_lanes(state, sources, donate=donate, targets=targets)

    def peek(self, state):
        trips, active, phases = _peek(state)
        return int(trips), np.asarray(active), np.asarray(phases)

    def take_row(self, state, lane):
        return np.asarray(_take_row(state.dist, jnp.int32(lane)))


class ShardedBackend:
    """Adapter over the mesh-sharded batch stepper.

    The same scheduler then serves continuous traffic against a graph whose
    vertex state lives block-partitioned across the device mesh — lanes are
    rows of the ``(B, n_pad)`` sharded state, and each scheduling round's
    ``step`` runs the fused shard_map phase loop (DESIGN.md Sec. 7).
    """

    def __init__(self, g: Graph, mesh, axes, schedule: str = "reduce_scatter",
                 pad_multiple: int = 8, criterion: str = DEFAULT_CRITERION):
        # imported lazily-ish at construction: the distributed module pulls
        # in shard_map machinery the static serving path never needs
        from repro.core.distributed import shard_graph_batch

        if isinstance(axes, str):
            axes = (axes,)
        self.g = g
        self.mesh = mesh
        self.axes = tuple(axes)
        self.schedule = schedule
        plan = _serving_plan(criterion)
        self.criterion = plan.criterion
        num = int(np.prod([mesh.shape[a] for a in self.axes]))
        # the backend's criterion is fixed for its lifetime, so only build
        # the transpose edge partition when the plan's dynamic OUT keys
        # will actually read it (it doubles resident edge memory)
        self.sg = shard_graph_batch(g, num, pad_multiple=pad_multiple,
                                    with_transpose=plan.needs_out_adjacency)

    @property
    def n(self) -> int:
        return self.g.n

    def init(self, lanes: int):
        from repro.core.distributed import init_sharded_batch_state

        return init_sharded_batch_state(
            self.sg, np.full(lanes, EMPTY_LANE, np.int32),
            criterion=self.criterion,
        )

    def step(self, state, k_phases, *, stop_on_lane_finish=True, donate=False):
        from repro.core.distributed import step_sharded_batch

        return step_sharded_batch(
            self.sg, state, self.mesh, self.axes, k_phases,
            schedule=self.schedule, stop_on_lane_finish=stop_on_lane_finish,
            donate=donate,
        )

    def reset_lanes(self, state, sources, *, donate=False, targets=None):
        from repro.core.distributed import reset_sharded_lanes

        if targets is not None:
            raise ValueError(
                "ShardedBackend does not support s->t target lanes; serve "
                "point queries through a point-capable StaticBackend"
            )
        return reset_sharded_lanes(state, sources, donate=donate)

    def peek(self, state):
        trips, active, phases = _peek(state)
        return int(trips), np.asarray(active), np.asarray(phases)

    def take_row(self, state, lane):
        # slice off the padding columns so consumers (cache, parity checks)
        # see the same (n,) row shape as the static backend
        return np.asarray(_take_row(state.dist, jnp.int32(lane)))[: state.n]


# ---------------------------------------------------------------------------
# Engine portfolio: measured policy x layout routing
# ---------------------------------------------------------------------------


def _degree_bucket(g: Graph) -> str:
    """``max/mean`` out-degree >= 4 reads as a skewed (power-law-ish) graph
    — the regime where the sliced layout and bucketed scheduling pay off —
    everything else as flat."""
    deg = np.asarray(out_degrees(g), np.float64)
    mean = float(deg.mean()) if deg.size else 0.0
    if mean <= 0.0:
        return "flat"
    return "skew" if float(deg.max()) / mean >= 4.0 else "flat"


def _weight_bucket(g: Graph) -> str:
    """Coefficient of variation of the (finite) edge weights: >= 0.9 reads
    as heavy-tailed (exponential sits at 1.0, uniform at ~0.58) — the
    regime where delta-stepping's bucket width choice actually matters."""
    w = np.asarray(g.w, np.float64)
    w = w[np.isfinite(w)]
    mean = float(w.mean()) if w.size else 0.0
    if mean <= 0.0:
        return "uniform"
    return "heavy" if float(w.std()) / mean >= 0.9 else "uniform"


def _depth_bucket(g: Graph) -> str:
    """Cheap hop-diameter proxy: one host BFS (out-edges, unweighted) from
    the max-out-degree vertex; eccentricity > 2*log2(n) reads as a deep
    (road/grid-like) graph, where phase counts scale with depth rather
    than log n and static criteria lose ground to dynamic ones. (A grid's
    centre eccentricity ~sqrt(n) clears the bound from ~6x6 up; expander
    families sit at O(log n) and never do.)"""
    from repro.core.graph import to_numpy_csr

    n = g.n
    if n <= 1:
        return "shallow"
    indptr, indices, _ = to_numpy_csr(g)
    counts_all = np.diff(indptr)
    start = int(np.argmax(counts_all))
    seen = np.zeros(n, bool)
    seen[start] = True
    frontier = np.array([start], np.int64)
    ecc = 0
    while frontier.size:
        starts = indptr[frontier]
        counts = counts_all[frontier]
        total = int(counts.sum())
        if total == 0:
            break
        offs = np.repeat(starts, counts) + (
            np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
        )
        nbr = np.unique(indices[offs])
        nbr = nbr[~seen[nbr]]
        if nbr.size == 0:
            break
        seen[nbr] = True
        frontier = nbr
        ecc += 1
    return "deep" if ecc > 2.0 * np.log2(n) else "shallow"


def graph_family(g: Graph) -> str:
    """Workload bucket the portfolio ledger keys on: ``<deg>-<wt>-<depth>``.

    Three cheap axes — degree skew (``flat``/``skew``), weight tail
    (``uniform``/``heavy``) and a BFS hop-diameter proxy
    (``shallow``/``deep``) — each the regime boundary for one routing
    decision: layout, bucket width, and criterion dynamism respectively.
    The buckets are deliberately crude: the ledger records *measurements*,
    so a family only needs to be stable enough that graphs sharing it rank
    the candidates the same way. Memoised on the graph instance (the depth
    proxy walks the CSR once); never contains ``:`` (ledger key syntax)
    or ``-``-free ambiguity — :func:`family_fallbacks` parses the leading
    axis back out for pre-rich-key ledger records.
    """
    fam = g.__dict__.get("_graph_family")
    if fam is None:
        fam = f"{_degree_bucket(g)}-{_weight_bucket(g)}-{_depth_bucket(g)}"
        g.__dict__["_graph_family"] = fam
    return fam


def family_fallbacks(family: str) -> tuple[str, ...]:
    """Ledger lookup order for a family key.

    The rich ``<deg>-<wt>-<depth>`` family first, then its leading degree
    bucket — which IS the whole family name records carried before the
    weight/depth axes existed — so a ledger written by an older process
    keeps routing traffic instead of forcing a re-probe.
    """
    coarse = family.split("-", 1)[0]
    return (family,) if coarse == family else (family, coarse)


@dataclasses.dataclass(frozen=True)
class EngineCandidate:
    """One engine configuration the portfolio may route a workload to."""

    policy: str  # policy spec ("in|out", "delta", ...)
    layout: str  # "padded" | "sliced"
    delta: float | None = None  # absolute bucket width override (delta only)
    delta_scale: float | None = None  # x default_delta(g): the graph-relative
    #   form a Delta-grid needs — an absolute width only means something for
    #   one weight distribution, a scale sweeps around the Meyer-Sanders
    #   default on every family

    @property
    def spec(self) -> str:
        return P.canonical_spec(self.policy)

    @property
    def ledger_policy(self) -> str:
        """The policy segment of the portfolio ledger key.

        Delta-grid members must not collide in the ledger, so the bucket
        override is part of the name; the no-override spelling stays the
        bare spec, keeping every pre-grid ledger record addressable.
        """
        if self.delta is not None:
            return f"{self.spec}@d{self.delta:g}"
        if self.delta_scale is not None:
            return f"{self.spec}@x{self.delta_scale:g}"
        return self.spec

    def resolve_delta(self, g: Graph) -> float | None:
        """The absolute bucket width this candidate runs ``g`` with."""
        if self.delta is not None:
            return float(self.delta)
        if self.delta_scale is not None:
            return float(self.delta_scale) * default_delta(g)
        return None  # policy default (default_delta) downstream


DEFAULT_CANDIDATES: tuple[EngineCandidate, ...] = (
    EngineCandidate("instatic|outstatic", "padded"),
    EngineCandidate("in|out", "padded"),
    EngineCandidate("in|out", "sliced"),
    EngineCandidate("delta", "padded"),
    EngineCandidate("delta", "sliced"),
    # Delta-grid around the Meyer-Sanders default (delta's strong layout):
    # bucket width steers the light/heavy phase split, and the best point
    # is a measured property of the family, not a closed form
    EngineCandidate("delta", "sliced", delta_scale=0.5),
    EngineCandidate("delta", "sliced", delta_scale=2.0),
    EngineCandidate("delta", "sliced", delta_scale=4.0),
)


def _attribution_totals(result, spec: str) -> dict[str, int]:
    """Sum the harvested ``settle_attribution`` ring over lanes and phases,
    restricted to the policy's share terms (criterion members, or
    light/heavy for delta — the bucket-id gauge is not summable)."""
    if result.settle_attribution is None:
        return {}
    pol = P.policy_for(spec)
    terms = pol.attribution_terms()
    share = set(pol.share_terms())
    attr = np.asarray(result.settle_attribution)  # (B, trace_len, T)
    return {
        t: int(attr[:, :, k].sum())
        for k, t in enumerate(terms)
        if t in share
    }


def measure_portfolio(
    g: Graph,
    *,
    lanes: int = 8,
    candidates: tuple[EngineCandidate, ...] = DEFAULT_CANDIDATES,
    ledger=None,
    use_pallas: bool = True,
    registry=None,
    repeats: int = 2,
) -> dict[tuple[str, str], dict]:
    """Probe every candidate on ``g`` and record measured entries.

    Each candidate solves the same ``lanes``-source batch twice: once with
    telemetry (doubles as compile warmup; yields phase counts and the
    policy's settle-attribution shares) and then timed without telemetry
    (median of ``repeats``). Entries land in the tuning ledger under
    :func:`~repro.kernels.config.portfolio_ledger_key` so later processes
    can route without re-probing; returns (ledger_policy, layout) -> entry
    (Delta-grid members carry their bucket override in the policy name).
    """
    from repro.kernels import config as kcfg
    from repro.obs.timer import timed

    if ledger is None:
        ledger = kcfg.global_ledger()
    family = graph_family(g)
    sources = (np.arange(lanes, dtype=np.int64) * 7919) % g.n
    out: dict[tuple[str, str], dict] = {}
    for cand in candidates:
        spec = cand.spec
        pol = P.policy_for(spec)
        kw: dict = {"criterion": spec, "layout": cand.layout,
                    "use_pallas": use_pallas}
        delta_eff = None
        if pol.uses_delta:
            delta_eff = cand.resolve_delta(g)
            kw["delta"] = delta_eff  # None -> default_delta(g) downstream
        probe = run_phased_static_batch(
            g, sources, trace_len=pol.phase_cap(g.n), telemetry=True, **kw
        )
        jax.block_until_ready(probe.dist)

        def solve(kw=kw):
            return jax.block_until_ready(
                run_phased_static_batch(g, sources, **kw).dist
            )

        # the telemetry probe compiled a *different* program (rings on),
        # so warm the timed one explicitly — timed() has no implicit warmup
        solve()
        wall_s, _ = timed(solve, repeats=repeats)
        entry = kcfg.record_portfolio(
            ledger, family, lanes, cand.ledger_policy, cand.layout,
            wall_s=wall_s,
            phases=int(np.asarray(probe.phases).sum()),
            queries=lanes,
            delta=delta_eff,
            attribution=_attribution_totals(probe, spec),
        )
        out[(cand.ledger_policy, cand.layout)] = entry
        if registry is not None:
            registry.gauge(
                f"portfolio.qps.{cand.ledger_policy}.{cand.layout}",
                "measured queries/s for one portfolio candidate",
            ).set(entry["qps"])
    return out


def pick_engine(
    family: str,
    lanes: int,
    candidates: tuple[EngineCandidate, ...] = DEFAULT_CANDIDATES,
    ledger=None,
) -> EngineCandidate:
    """The measured-best candidate for (family, lanes) from the ledger.

    Ranks by recorded qps over the candidates that have entries, reading
    the rich family key first and falling back to its pre-rich coarse
    degree bucket (:func:`family_fallbacks`); with no entries at all the
    first candidate (the paper's default criterion) is the safe fallback —
    routing never blocks on a probe.
    """
    from repro.kernels import config as kcfg

    if ledger is None:
        ledger = kcfg.global_ledger()
    entries: dict = {}
    for fam in family_fallbacks(family):
        entries = kcfg.portfolio_entries(ledger, fam, lanes)
        if entries:
            break
    best, best_qps = None, -1.0
    for cand in candidates:
        entry = entries.get((cand.ledger_policy, cand.layout))
        if entry is not None and entry.get("qps", 0.0) > best_qps:
            best, best_qps = cand, float(entry["qps"])
    return best if best is not None else candidates[0]


class PortfolioBackend:
    """An :class:`EngineBackend` that picks its engine from the ledger.

    At construction it resolves ``graph_family(g)``, consults the tuning
    ledger's portfolio records for that (family, lanes) and instantiates
    the measured-best policy x layout as an inner :class:`StaticBackend`
    (``probe=True`` — or an empty ledger — runs :func:`measure_portfolio`
    first, so the first server against a new family pays one probe and
    every later one routes from the recorded entries). All five protocol
    methods delegate, so the scheduler sees an ordinary backend whose
    ``criterion`` reflects the routed policy.
    """

    def __init__(self, g: Graph, lanes_hint: int = 8,
                 candidates: tuple[EngineCandidate, ...] = DEFAULT_CANDIDATES,
                 ledger=None, use_pallas: bool = True, probe: bool = False,
                 registry=None, point_queries: bool = False):
        from repro.kernels import config as kcfg

        if not candidates:
            raise ValueError("candidates must be non-empty")
        if ledger is None:
            ledger = kcfg.global_ledger()
        self.family = graph_family(g)
        self.lanes_hint = int(lanes_hint)
        if probe or not any(
            kcfg.portfolio_entries(ledger, fam, self.lanes_hint)
            for fam in family_fallbacks(self.family)
        ):
            measure_portfolio(
                g, lanes=self.lanes_hint, candidates=candidates,
                ledger=ledger, use_pallas=use_pallas, registry=registry,
            )
        self.choice = pick_engine(self.family, self.lanes_hint, candidates,
                                  ledger)
        self.inner = StaticBackend(
            g, use_pallas=use_pallas, layout=self.choice.layout,
            policy=self.choice.policy, delta=self.choice.resolve_delta(g),
            point_queries=point_queries,
        )
        self.g = g
        self.criterion = self.inner.criterion
        self.point_queries = self.inner.point_queries

    @property
    def n(self) -> int:
        return self.inner.n

    def init(self, lanes: int) -> BatchState:
        return self.inner.init(lanes)

    def step(self, state, k_phases, *, stop_on_lane_finish=True, donate=False):
        return self.inner.step(state, k_phases,
                               stop_on_lane_finish=stop_on_lane_finish,
                               donate=donate)

    def reset_lanes(self, state, sources, *, donate=False, targets=None):
        return self.inner.reset_lanes(state, sources, donate=donate,
                                      targets=targets)

    def peek(self, state):
        return self.inner.peek(state)

    def take_row(self, state, lane):
        return self.inner.take_row(state, lane)
