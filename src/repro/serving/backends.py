"""Engine backend adapters: the seam between scheduler and solver.

:class:`ContinuousBatcher` never touches an engine directly — it drives an
:class:`EngineBackend`, a five-method adapter (``init`` / ``step`` /
``reset_lanes`` / ``peek`` / ``take_row``) over any resumable B-lane phase
stepper. Two implementations exist:

  * :class:`StaticBackend` — the single-device Pallas stepper
    (``repro.core.static_engine``): ``(B, n)`` state, ELL pull kernels.
  * :class:`ShardedBackend` — the mesh stepper
    (``repro.core.distributed``): ``(B, n_pad)`` state block-sharded over
    the mesh's vertex axis, COO push + one vector collective per phase.

Both expose identical semantics — a lane is a fixed point when empty or
finished, a reset lane is bitwise a fresh solve, ``stop_on_lane_finish``
ends a chunk on the first lane termination — so the scheduler's
admission/coalescing/cache/metrics machinery is backend-agnostic and every
completed request's distances are bit-exact against a standalone
``run_phased_static`` solve regardless of which engine served it
(pinned by the shared parametrised test in ``tests/test_serving.py``).
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import criteria as C
from repro.core.graph import (
    Graph,
    to_ell_in,
    to_ell_in_sliced,
    to_ell_out,
    to_ell_out_sliced,
)
from repro.core.static_engine import (
    DEFAULT_CRITERION,
    EMPTY_LANE,
    BatchState,
    init_batch_state,
    reset_lanes,
    step_batch,
)


def _serving_plan(criterion: str) -> C.CritPlan:
    """Validate and canonicalise a serving criterion.

    'oracle' is rejected: a clairvoyant criterion needs the answer it is
    supposed to compute as input, which no online workload has.
    """
    plan = C.plan_for(criterion)
    if plan.needs_oracle:
        raise ValueError(
            "serving backends cannot run the 'oracle' criterion: it requires "
            "per-query true distances up front"
        )
    return plan


@jax.jit
def _peek(state):
    """One fused device read per step: (trips, per-lane live flag, phases)."""
    return state.trips, jnp.any(state.status == 1, axis=1), state.phases


@jax.jit
def _take_row(dist, lane):
    # traced lane index -> one compile total (a python-int index or a
    # variable-length fancy-index would recompile per lane / per count)
    return jax.lax.dynamic_index_in_dim(dist, lane, keepdims=False)


@runtime_checkable
class EngineBackend(Protocol):
    """What the scheduler needs from a resumable B-lane engine."""

    g: Graph
    criterion: str  # canonical criterion string the engine solves with —
    #   part of the serving cache key: rows computed under different criteria
    #   coincide only in exact arithmetic, so they must never share entries

    @property
    def n(self) -> int:
        """Vertex count queries are validated against."""
        ...

    def init(self, lanes: int):
        """Fresh all-empty state with ``lanes`` lanes."""
        ...

    def step(self, state, k_phases: int, *, stop_on_lane_finish: bool = True,
             donate: bool = False):
        """Advance up to ``k_phases`` trips (early exit on lane finish)."""
        ...

    def reset_lanes(self, state, sources: np.ndarray, *, donate: bool = False):
        """Re-init the lanes ``sources`` selects (KEEP_LANE passes through)."""
        ...

    def peek(self, state) -> tuple[int, np.ndarray, np.ndarray]:
        """(trips, (B,) bool live flags, (B,) int phases) — one device sync."""
        ...

    def take_row(self, state, lane: int) -> np.ndarray:
        """Lane ``lane``'s (n,) f32 distance row as a fresh host-owned array
        (never aliasing the state buffers — the scheduler donates those to
        the next engine call)."""
        ...


class StaticBackend:
    """Adapter over the single-device static-engine stepper.

    ``layout`` selects the resident adjacency views ("padded" ELL or the
    degree-sliced "sliced" layout — bit-identical results, the sliced one
    wins on skewed degree distributions); an explicit ``ell`` overrides it.
    Execution mode / tile sizes resolve through ``repro.kernels.config``
    (env overrides + tuning ledger), so a server process tuned at startup
    serves every later query with the tuned configuration.
    """

    def __init__(self, g: Graph, ell=None, use_pallas: bool = True,
                 criterion: str = DEFAULT_CRITERION, layout: str = "padded"):
        plan = _serving_plan(criterion)
        if layout not in ("padded", "sliced"):
            raise ValueError(
                f"layout must be 'padded' or 'sliced'; got {layout!r}"
            )
        sliced = layout == "sliced"
        self.g = g
        if ell is None:
            ell = to_ell_in_sliced(g) if sliced else to_ell_in(g)
        self.ell = ell
        self.ell_out = None
        if plan.needs_out_adjacency:
            self.ell_out = to_ell_out_sliced(g) if sliced else to_ell_out(g)
        self.use_pallas = bool(use_pallas)
        self.criterion = plan.criterion

    @property
    def n(self) -> int:
        return self.g.n

    def init(self, lanes: int) -> BatchState:
        return init_batch_state(self.g, np.full(lanes, EMPTY_LANE, np.int32),
                                criterion=self.criterion)

    def step(self, state, k_phases, *, stop_on_lane_finish=True, donate=False):
        return step_batch(
            self.g, state, k_phases, ell=self.ell, use_pallas=self.use_pallas,
            stop_on_lane_finish=stop_on_lane_finish, donate=donate,
            ell_out=self.ell_out,
        )

    def reset_lanes(self, state, sources, *, donate=False):
        return reset_lanes(state, sources, donate=donate)

    def peek(self, state):
        trips, active, phases = _peek(state)
        return int(trips), np.asarray(active), np.asarray(phases)

    def take_row(self, state, lane):
        return np.asarray(_take_row(state.dist, jnp.int32(lane)))


class ShardedBackend:
    """Adapter over the mesh-sharded batch stepper.

    The same scheduler then serves continuous traffic against a graph whose
    vertex state lives block-partitioned across the device mesh — lanes are
    rows of the ``(B, n_pad)`` sharded state, and each scheduling round's
    ``step`` runs the fused shard_map phase loop (DESIGN.md Sec. 7).
    """

    def __init__(self, g: Graph, mesh, axes, schedule: str = "reduce_scatter",
                 pad_multiple: int = 8, criterion: str = DEFAULT_CRITERION):
        # imported lazily-ish at construction: the distributed module pulls
        # in shard_map machinery the static serving path never needs
        from repro.core.distributed import shard_graph_batch

        if isinstance(axes, str):
            axes = (axes,)
        self.g = g
        self.mesh = mesh
        self.axes = tuple(axes)
        self.schedule = schedule
        plan = _serving_plan(criterion)
        self.criterion = plan.criterion
        num = int(np.prod([mesh.shape[a] for a in self.axes]))
        # the backend's criterion is fixed for its lifetime, so only build
        # the transpose edge partition when the plan's dynamic OUT keys
        # will actually read it (it doubles resident edge memory)
        self.sg = shard_graph_batch(g, num, pad_multiple=pad_multiple,
                                    with_transpose=plan.needs_out_adjacency)

    @property
    def n(self) -> int:
        return self.g.n

    def init(self, lanes: int):
        from repro.core.distributed import init_sharded_batch_state

        return init_sharded_batch_state(
            self.sg, np.full(lanes, EMPTY_LANE, np.int32),
            criterion=self.criterion,
        )

    def step(self, state, k_phases, *, stop_on_lane_finish=True, donate=False):
        from repro.core.distributed import step_sharded_batch

        return step_sharded_batch(
            self.sg, state, self.mesh, self.axes, k_phases,
            schedule=self.schedule, stop_on_lane_finish=stop_on_lane_finish,
            donate=donate,
        )

    def reset_lanes(self, state, sources, *, donate=False):
        from repro.core.distributed import reset_sharded_lanes

        return reset_sharded_lanes(state, sources, donate=donate)

    def peek(self, state):
        trips, active, phases = _peek(state)
        return int(trips), np.asarray(active), np.asarray(phases)

    def take_row(self, state, lane):
        # slice off the padding columns so consumers (cache, parity checks)
        # see the same (n,) row shape as the static backend
        return np.asarray(_take_row(state.dist, jnp.int32(lane)))[: state.n]
