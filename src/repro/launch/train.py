"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b \
        --steps 1000 --batch 256 --seq 4096 --ckpt-dir gs://.../ckpts

On a real fleet this runs per-host under jax.distributed; here it drives the
same code path on the local device set. The mesh defaults to the production
(16, 16) layout when 256 devices are visible, else the largest host mesh.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim.adamw import OptConfig
from repro.runtime.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    n = len(jax.devices())
    mesh = make_production_mesh() if n >= 256 else make_host_mesh(tp=min(2, n))
    res = train(
        cfg, mesh, steps=args.steps,
        dcfg=DataConfig(seed=0, batch=args.batch, seq_len=args.seq),
        opt_cfg=OptConfig(lr=args.lr, total_steps=args.steps,
                          m_dtype="bfloat16", v_mode="factored"),
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )
    print(f"done: final loss {res.losses[-1]:.4f} "
          f"(skipped {res.skipped_steps} poisoned steps)")


if __name__ == "__main__":
    main()
