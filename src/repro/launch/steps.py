"""Entry-point builders for training/serving steps + abstract input specs.

Everything here is shape-only-safe: ``abstract_*`` functions build
ShapeDtypeStruct pytrees via ``jax.eval_shape`` (zero device allocation), so
the multi-pod dry-run can lower/compile full-size 400B-parameter cells on a
CPU host.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec
from repro.models import decode_step, init_cache, init_params, prefill, train_loss
from repro.models.layers import ShardingCtx
from repro.optim.adamw import OptConfig, apply_updates, init_opt_state, state_specs_for
from repro.sharding.partition import (
    add_fsdp,
    batch_specs,
    cache_specs,
    param_specs,
    to_shardings,
)

BF16 = jnp.bfloat16

# params whose TP-sharded residency exceeds this use FSDP over the data axis
FSDP_BYTES_PER_CHIP = 6 << 30


def _param_bytes(params_shape) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree.leaves(params_shape)
    )


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def abstract_params(cfg: ModelConfig, dtype=BF16):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0), dtype))


def abstract_opt_state(cfg: ModelConfig, opt_cfg: OptConfig, params_shape):
    return jax.eval_shape(partial(init_opt_state, cfg=opt_cfg), params_shape)


def abstract_batch(cfg: ModelConfig, spec: ShapeSpec, with_labels: bool):
    B, S = spec.global_batch, spec.seq_len
    batch: dict[str, Any] = {}
    if cfg.embeddings_in:
        batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), BF16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.n_vision_tokens:
        batch["vision"] = jax.ShapeDtypeStruct(
            (B, cfg.n_vision_tokens, cfg.d_model), BF16)
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return batch


def abstract_cache(cfg: ModelConfig, batch: int, prefix_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, prefix_len, BF16))


@dataclasses.dataclass
class Cell:
    """A lowerable (arch x shape x mesh) dry-run cell."""

    name: str
    fn: Any  # jitted
    args: tuple  # ShapeDtypeStructs (or arrays)

    def lower(self):
        return self.fn.lower(*self.args)


def input_specs(cfg: ModelConfig, shape_name: str):
    """Public helper: ShapeDtypeStruct stand-ins for every model input of the
    given shape cell (the pattern the dry-run consumes)."""
    spec = SHAPES[shape_name]
    if spec.kind == "train":
        return abstract_batch(cfg, spec, with_labels=True)
    if spec.kind == "prefill":
        return abstract_batch(cfg, spec, with_labels=False)
    tokens = jax.ShapeDtypeStruct((spec.global_batch, 1), jnp.int32)
    cache = abstract_cache(cfg, spec.global_batch, spec.seq_len)
    return {"tokens": tokens, "cache": cache,
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


ACT_BUDGET_BYTES = 6 << 30  # per-chip activation budget driving microbatching


def auto_microbatches(cfg: ModelConfig, spec: ShapeSpec, dp_size: int,
                      tp_size: int) -> int:
    """Smallest power-of-two accumulation count whose per-microbatch residual
    stack (+ transient factor 3x) fits the activation budget."""
    b_loc = max(spec.global_batch // dp_size, 1)
    act = cfg.n_layers * b_loc * spec.seq_len * cfg.d_model * 2 * 3 // tp_size
    a = 1
    while act // a > ACT_BUDGET_BYTES and a < max(spec.global_batch // dp_size, 1):
        a *= 2
    return a


def build_cell(cfg: ModelConfig, shape_name: str, mesh: Mesh,
               opt_cfg: OptConfig | None = None, remat: bool = True,
               use_shd: bool = True, donate: bool = True,
               fsdp: bool | str = "auto",
               microbatches: int | str = "auto",
               remat_policy: str = "full") -> Cell:
    """Construct the jitted step + abstract args for one dry-run cell."""
    spec = SHAPES[shape_name]
    dp = data_axes(mesh)
    shd = ShardingCtx(dp=dp, tp="model", mesh=mesh) if use_shd else None
    pshape = abstract_params(cfg)
    pspecs = param_specs(cfg, pshape)
    tp_size = mesh.shape.get("model", 1)
    if fsdp == "auto":
        fsdp = _param_bytes(pshape) // tp_size > FSDP_BYTES_PER_CHIP
    if fsdp:
        pspecs = add_fsdp(pspecs, pshape, axis="data", size=mesh.shape["data"])
    pshard = to_shardings(mesh, pspecs)

    if spec.kind == "train":
        opt_cfg = opt_cfg or OptConfig(
            m_dtype="bfloat16", v_mode="factored", total_steps=10000
        )
        dp_size = int(np.prod([mesh.shape[a] for a in dp]))
        if microbatches == "auto":
            microbatches = auto_microbatches(cfg, spec, dp_size, tp_size)
        A = max(int(microbatches), 1)
        oshape = abstract_opt_state(cfg, opt_cfg, pshape)
        oshard = to_shardings(mesh, state_specs_for(oshape, pspecs))
        bshape = abstract_batch(cfg, spec, with_labels=True)
        bshard = to_shardings(mesh, batch_specs(cfg, bshape, dp, mesh))

        def loss_fn(p, b):
            return train_loss(cfg, p, b, shd, remat=remat,
                              remat_policy=remat_policy)

        def step(params, opt_state, batch):
            if A == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                # gradient accumulation over A microbatches (f32 accumulator)
                mb = jax.tree.map(
                    lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:]),
                    batch,
                )

                def constrain(tree):  # accumulator must shard like the params
                    return jax.tree.map(
                        lambda x, s: jax.lax.with_sharding_constraint(x, s),
                        tree, pspecs,
                        is_leaf=lambda x: not isinstance(x, (dict, P)),
                    )

                def micro(carry, b):
                    lsum, gacc = carry
                    l, g = jax.value_and_grad(loss_fn)(params, b)
                    gacc = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), gacc, g
                    )
                    return (lsum + l, constrain(gacc)), None

                zeros = constrain(jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                ))
                (loss, gsum), _ = jax.lax.scan(
                    micro, (jnp.float32(0.0), zeros), mb
                )
                loss = loss / A
                grads = jax.tree.map(lambda g, p: (g / A).astype(p.dtype),
                                     gsum, params)
            params, opt_state, stats = apply_updates(params, grads, opt_state, opt_cfg)
            return params, opt_state, loss, stats["gnorm"]

        fn = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            donate_argnums=(0, 1) if donate else (),
        )
        return Cell(f"{cfg.name}/{shape_name}", fn, (pshape, oshape, bshape))

    if spec.kind == "prefill":
        bshape = abstract_batch(cfg, spec, with_labels=False)
        bshard = to_shardings(mesh, batch_specs(cfg, bshape, dp, mesh))

        if cfg.encoder_only:
            # encoders have no KV cache: "prefill" = batched encode forward
            from repro.models import forward_logits

            def pre(params, batch):
                return forward_logits(cfg, params, batch, shd)
        else:
            def pre(params, batch):
                return prefill(cfg, params, batch, shd)

        fn = jax.jit(pre, in_shardings=(pshard, bshard))
        return Cell(f"{cfg.name}/{shape_name}", fn, (pshape, bshape))

    # decode
    tshape = jax.ShapeDtypeStruct((spec.global_batch, 1), jnp.int32)
    cshape = abstract_cache(cfg, spec.global_batch, spec.seq_len)
    cshard = to_shardings(mesh, cache_specs(cfg, cshape, dp, mesh))
    tshard = to_shardings(mesh,
                          batch_specs(cfg, {"tokens": tshape}, dp, mesh))["tokens"]

    def serve_step(params, tokens, cache, pos):
        return decode_step(cfg, params, tokens, cache, pos, shd)

    fn = jax.jit(
        serve_step,
        in_shardings=(pshard, tshard, cshard, None),
        donate_argnums=(2,) if donate else (),
    )
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return Cell(f"{cfg.name}/{shape_name}", fn, (pshape, tshape, cshape, pos))
