import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production meshes and extract memory/cost/collective statistics.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --workload sssp --mesh multipod

Each cell writes a JSON record: per-device bytes (memory_analysis), HLO FLOPs
and bytes-accessed (cost_analysis), and per-kind collective bytes parsed from
the optimized HLO. benchmarks/roofline.py consumes these records.
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs import ALIASES, SHAPES, get_config, runnable_shapes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z]+[0-9]+(?:e[0-9]+m[0-9]+)?)\[([0-9,]*)\]")


def _result_bytes(line: str, kind: str) -> int:
    """Bytes of the result type(s) of a collective op line.

    Handles both scalar results (``bf16[...] all-to-all(``) and tuple results
    (``(f32[...], f32[...]) all-to-all(``): everything between '=' and the op
    name is the result type."""
    parts = line.split(" = ", 1)
    if len(parts) != 2:
        return 0
    rhs = parts[1]
    pos = rhs.find(f" {kind}(")
    if pos < 0:
        pos = rhs.find(f" {kind}-start(")
    if pos < 0:
        return 0
    total = 0
    for m in _SHAPE_RE.finditer(rhs[:pos]):
        dt, dims = m.group(1), m.group(2)
        sz = _DTYPE_BYTES.get(dt)
        if sz is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * sz
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind result bytes summed over the module.

    Convention (documented in EXPERIMENTS.md): we count the *result* bytes of
    each collective. For all-reduce the wire traffic of a ring is ~2x the
    result; for all-gather the result ~equals the received bytes; for
    reduce-scatter / all-to-all the result ~equals the received bytes. The
    roofline's collective term applies the 2x for all-reduce explicitly.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        for kind in _COLLECTIVES:
            if f" {kind}(" in s or f" {kind}-start(" in s:
                out[kind] += _result_bytes(s, kind)
                break
    return out


def _probe_stats(cfg, shape, mesh, remat, use_shd):
    """Compile depth-1 and depth-2 variants and linearly extrapolate FLOPs /
    bytes / collective bytes to the full depth.

    XLA's HloCostAnalysis counts a while-loop body ONCE (trip count is
    dynamic), so the raw cost_analysis of a scan-over-units model
    undercounts by ~n_units. stats(U) is affine in U (per-unit cost is
    exactly repeated), so two probe compiles recover the true totals:
      total(U) = s1 + (s2 - s1) * (U - 1).
    """
    import dataclasses as dc

    plen = len(cfg.pattern)
    out = {}
    for u in (1, 2):
        # inner lax.scans (attention q-chunks, CE chunks, grad-accumulation)
        # are ALSO while loops whose bodies XLA counts once; the probe
        # compiles disable them (single chunk / single microbatch) so the
        # unit loop is the only repetition and the affine model is exact.
        c = dc.replace(cfg, n_layers=plen * u, attn_chunk=1 << 24,
                       ce_chunk=1 << 24)
        with mesh:
            cell = build_cell(c, shape, mesh, remat=remat, use_shd=use_shd,
                              microbatches=1)
            compiled = cell.lower().compile()
            cost = compiled.cost_analysis()
            out[u] = {
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
                "collectives": collective_bytes(compiled.as_text()),
            }
    U = cfg.n_units
    ext = {
        "flops": out[1]["flops"] + (out[2]["flops"] - out[1]["flops"]) * (U - 1),
        "bytes_accessed": out[1]["bytes_accessed"]
        + (out[2]["bytes_accessed"] - out[1]["bytes_accessed"]) * (U - 1),
        "collectives": {
            k: out[1]["collectives"][k]
            + (out[2]["collectives"][k] - out[1]["collectives"][k]) * (U - 1)
            for k in out[1]["collectives"]
        },
    }
    return ext


def run_cell(arch: str, shape: str, mesh_kind: str, remat: bool = True,
             use_shd: bool = True, probe: bool = True,
             remat_policy: str = "full") -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    rec: dict = {
        "arch": cfg.name, "shape": shape, "mesh": mesh_kind,
        "mesh_shape": dict(zip(mesh.axis_names,
                               np.asarray(mesh.devices.shape).tolist())),
        "chips": int(np.prod(mesh.devices.shape)),
    }
    skip = runnable_shapes(cfg)[shape]
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec
    rec["remat_policy"] = remat_policy
    t0 = time.monotonic()
    try:
        with mesh:
            cell = build_cell(cfg, shape, mesh, remat=remat, use_shd=use_shd,
                              remat_policy=remat_policy)
            lowered = cell.lower()
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops=float(cost.get("flops", -1.0)),
            bytes_accessed=float(cost.get("bytes accessed", -1.0)),
            collectives=collective_bytes(hlo),
            memory={
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "generated_code_bytes": int(
                    getattr(mem, "generated_code_size_in_bytes", 0)),
            },
        )
        if probe:
            rec["extrapolated"] = _probe_stats(cfg, shape, mesh, remat, use_shd)
    except Exception as e:  # noqa: BLE001 — a failing cell is a result, not a crash
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def run_sssp(mesh_kind: str, n_vertices: int = 1 << 24, avg_deg: int = 16,
             schedule: str = "reduce_scatter") -> dict:
    """Dry-run the paper's own workload: distributed phased SSSP on the
    production mesh (vertices sharded over every mesh axis)."""
    import jax.numpy as jnp

    from repro.core.distributed import ShardedGraph, make_distributed_sssp

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    axes = mesh.axis_names
    P = int(np.prod(mesh.devices.shape))
    n_loc = -(-n_vertices // P)
    n_pad = n_loc * P
    e_loc = n_loc * avg_deg
    f32 = jax.ShapeDtypeStruct
    sg = ShardedGraph(
        n=n_vertices, n_pad=n_pad, n_loc=n_loc, num_shards=P,
        src_local=f32((P, e_loc), jnp.int32),
        dst=f32((P, e_loc), jnp.int32),
        w=f32((P, e_loc), jnp.float32),
        d_init=f32((n_pad,), jnp.float32),
        status_init=f32((n_pad,), jnp.int32),
        in_min=f32((n_pad,), jnp.float32),
        out_min=f32((n_pad,), jnp.float32),
    )
    rec = {
        "arch": f"sssp-n{n_vertices}-d{avg_deg}-{schedule}",
        "shape": "phased_sssp", "mesh": mesh_kind, "chips": P,
    }
    t0 = time.monotonic()
    try:
        with mesh:
            fn = make_distributed_sssp(mesh, axes, schedule=schedule)
            lowered = fn.lower(sg, jax.ShapeDtypeStruct((), jnp.int32))
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        rec.update(
            status="ok",
            compile_s=round(time.monotonic() - t0, 1),
            flops=float(cost.get("flops", -1.0)),
            bytes_accessed=float(cost.get("bytes accessed", -1.0)),
            collectives=collective_bytes(hlo),
            memory={
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            },
        )
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (assignment or module name)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true", help="every (arch x shape)")
    ap.add_argument("--workload", default="lm", choices=["lm", "sssp"])
    ap.add_argument("--schedule", default="reduce_scatter",
                    choices=["reduce_scatter", "allreduce"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-shd", action="store_true",
                    help="disable activation sharding constraints (baseline)")
    ap.add_argument("--out", default=None, help="output dir for JSON records")
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    records = []
    if args.workload == "sssp":
        for mk in meshes:
            rec = run_sssp(mk, schedule=args.schedule)
            print(json.dumps(rec, indent=None, default=str))
            records.append(rec)
    else:
        archs = list(ALIASES) if args.all or not args.arch else [args.arch]
        shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
        for mk in meshes:
            for a in archs:
                for s in shapes:
                    rec = run_cell(a, s, mk, remat=not args.no_remat,
                                   use_shd=not args.no_shd)
                    brief = {k: v for k, v in rec.items() if k != "traceback"}
                    print(json.dumps(brief, default=str), flush=True)
                    records.append(rec)
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        tag = f"{args.workload}_{args.mesh}_{args.arch or 'all'}_{args.shape or 'all'}"
        tag = tag.replace("/", "_").replace(".", "_")
        with open(os.path.join(args.out, f"{tag}.json"), "w") as f:
            json.dump(records, f, indent=1, default=str)


if __name__ == "__main__":
    main()
