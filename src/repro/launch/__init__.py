"""launch substrate."""
