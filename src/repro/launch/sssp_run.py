"""Distributed SSSP launcher: run the paper's phased algorithm over the
device mesh (vertex-partitioned, INSTATIC|OUTSTATIC criteria).

    PYTHONPATH=src python -m repro.launch.sssp_run --n 100000 --deg 10 \
        --schedule reduce_scatter
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core import dijkstra_numpy
from repro.core.distributed import run_distributed
from repro.graphs import uniform_gnp
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.obs.timer import Stopwatch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50000)
    ap.add_argument("--deg", type=float, default=10.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--schedule", default="reduce_scatter",
                    choices=["reduce_scatter", "allreduce"])
    ap.add_argument("--verify", action="store_true")
    args = ap.parse_args()

    g = uniform_gnp(args.n, args.deg / args.n, seed=args.seed)
    ndev = len(jax.devices())
    mesh = make_production_mesh() if ndev >= 256 else make_host_mesh(tp=1)
    axes = tuple(mesh.axis_names)
    print(f"mesh {dict(mesh.shape)}; schedule={args.schedule}")
    with Stopwatch() as sw:
        dist, phases = run_distributed(g, mesh, axes, 0, schedule=args.schedule)
        np.asarray(dist)
    print(f"n={g.n}: {int(phases)} phases in {sw.elapsed:.2f}s "
          f"(incl. compile)")
    if args.verify:
        ref = dijkstra_numpy(g, 0)
        fin = np.isfinite(ref)
        ok = np.allclose(np.asarray(dist)[fin], ref[fin], rtol=1e-5)
        print(f"verified against sequential Dijkstra: {ok}")


if __name__ == "__main__":
    main()
